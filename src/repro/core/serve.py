"""Streaming FL ingest: the sustained-throughput serving pipeline
(DESIGN.md §12.3).

``AsyncBuffered`` answers "is buffered-async *correct*" — lazy local
training, exact byte accounting, heap-oracle event order. This module
answers "how fast can the *server* ingest": a continuous-arrival loop
where encoded payloads stream in from an N-client population, the first-K
buffer fires a fused decode→aggregate (the PR 6 grouped/kernel path for
kernel-spec AEs), the global model updates, and exactly those K clients
are re-dispatched — all staged as **one donated jitted step**:

* event queue, client versions, and the flat global model are stacked
  device arrays (the §12.1 SoA layout with nothing host-side at all);
  the first-K pop is :func:`repro.core.arrival.pop_k_device`
  (``lax.sort`` on the ``(time, seq)`` key pair);
* synthetic encoded payloads are generated *in encoded space* on device
  (PRNG keyed on the dispatch sequence), so the step prices exactly the
  server's work — decode + staleness-weighted aggregate + re-dispatch —
  with zero host payload traffic;
* ``jax.jit(step, donate_argnums=0)`` donates the whole state pytree:
  XLA writes round r+1's state into round r's buffers, so the
  steady-state footprint is **two** generations of state (the classic
  double-buffer), not one per round. The invariant donation imposes: the
  caller must treat the passed-in state as consumed — :func:`run_serve`
  holds only the returned reference, never the donated one;
* per-round *host* work is O(1) — one dispatch of a cached executable —
  beating the O(cohort) the FedBuff regime requires (ISSUE 7); the
  benchmark asserts populations of 10^5+ at cohorts 256/4096/65536;
* ``shard=True`` ``shard_map``s the cohort axis of the decode→aggregate
  across a 1-D ``clients`` device mesh (same layout as
  ``codec.decode_and_aggregate_sharded``, here inlined into the donated
  step so the pop/re-dispatch stays fused around it).

Simulation caveats vs the exact scheduler: times are device ``float32``
(the heap oracle's float64 lexicographic exactness is not needed — ties
still break deterministically on ``seq``), latency is an in-jit uniform
jitter + straggler-tail model rather than ``LatencyModel``'s host
SeedSequence streams, and no local training happens (payloads are
synthetic). Throughput numbers are reported by ``benchmarks/tables.py``
``fl_serve`` (rounds/sec and ingested bytes/sec).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.arrival import pop_k_device

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static shape of the serving simulation (hashable — the jitted step
    specializes on it). ``spec`` is any codec spec; its ``size`` fixes the
    flat model width the aggregate updates."""

    n_clients: int
    buffer_k: int
    spec: codec.CodecSpec
    staleness_power: float = 0.5
    server_lr: float = 1.0
    base_latency: float = 1.0
    jitter: float = 0.5                # latency ~ base * U[1-j, 1+j]
    straggler_frac: float = 0.0        # first ceil(frac*N) clients slow
    straggler_mult: float = 10.0
    seed: int = 0
    shard: bool = False                # shard_map the cohort axis

    def __post_init__(self):
        assert 0 < self.buffer_k <= self.n_clients


def _latency(cfg: ServeConfig, key: jax.Array, cis: jax.Array) -> jax.Array:
    """Per-dispatch simulated round-trip latency for clients ``cis`` —
    the in-jit counterpart of ``LatencyModel.sample`` (same shape: base ×
    uniform jitter × straggler tail), PRNG-keyed per call."""
    u = jax.random.uniform(key, cis.shape, dtype=jnp.float32)
    lat = cfg.base_latency * (1.0 + cfg.jitter * (2.0 * u - 1.0))
    n_slow = int(np.ceil(cfg.straggler_frac * cfg.n_clients))
    if n_slow:
        lat = jnp.where(cis < n_slow, lat * cfg.straggler_mult, lat)
    return lat


def synthetic_payloads(spec: codec.CodecSpec, params: Optional[Pytree],
                       k: int, key: jax.Array) -> codec.Payload:
    """A stacked cohort of ``k`` synthetic encoded payloads with exactly
    the structure/shapes/dtypes ``codec.encode`` would ship for ``spec``
    (structure from ``jax.eval_shape`` — nothing is actually encoded).
    Floats draw standard normals, integer entries (quantized values,
    top-k indices) draw uniformly in range — the *decode* cost is what
    the serve loop prices, and decode cost is payload-value-independent
    for every codec in the union."""
    shapes = jax.eval_shape(
        lambda f: codec.encode(spec, params, f),
        jax.ShapeDtypeStruct((spec.size,), jnp.float32))
    leaves, treedef = jax.tree_util.tree_flatten(shapes)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for kk, s in zip(keys, leaves):
        shape = (k, *s.shape)
        if jnp.issubdtype(s.dtype, jnp.floating):
            out.append(jax.random.normal(kk, shape).astype(s.dtype))
        elif jnp.issubdtype(s.dtype, jnp.integer):
            lo, hi = ((-127, 128) if s.dtype == jnp.int8
                      else (0, max(int(spec.size), 2)))
            out.append(jax.random.randint(kk, shape, lo, hi,
                                          dtype=jnp.int32).astype(s.dtype))
        else:
            out.append(jnp.zeros(shape, s.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def init_state(cfg: ServeConfig, codec_params: Optional[Pytree] = None,
               global_flat: Optional[jax.Array] = None) -> Dict[str, Any]:
    """The device-resident serve state (one dict pytree, all arrays):
    every client dispatched at t=0 with the v0 model — the same opening
    position as ``AsyncBuffered._reset``."""
    n = cfg.n_clients
    key = jax.random.PRNGKey(cfg.seed)
    cis = jnp.arange(n, dtype=jnp.int32)
    if global_flat is None:
        global_flat = jnp.zeros((int(cfg.spec.size),), jnp.float32)
    return {
        "times": _latency(cfg, key, cis),            # (N,) next arrival
        "seqs": cis,                                 # (N,) dispatch seq
        "versions": jnp.zeros(n, jnp.int32),         # (N,) model at dispatch
        "global_flat": jnp.asarray(global_flat, jnp.float32),
        "clock": jnp.float32(0.0),
        "version": jnp.int32(0),
        "next_seq": jnp.int32(n),
    }


def _decode_aggregate(cfg: ServeConfig, params: Optional[Pytree],
                      stacked: codec.Payload, w: jax.Array) -> jax.Array:
    if not cfg.shard:
        return codec.decode_and_aggregate(cfg.spec, params, stacked, w)
    # cohort axis over a 1-D device mesh, inlined into the donated step:
    # each device reduces its shard's weighted sum (weights are globally
    # normalized), one psum makes the mean — codec.py §7.2 layout
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("clients",))
    assert cfg.buffer_k % mesh.devices.size == 0, (
        f"buffer_k={cfg.buffer_k} must divide over {mesh.devices.size} "
        "devices")

    def shard_fn(p, stacked_shard, w_shard):
        rows = codec.decode_batched(cfg.spec, p, stacked_shard)
        return jax.lax.psum(
            jnp.einsum("c,cp->p", w_shard.astype(jnp.float32),
                       rows.astype(jnp.float32)), "clients")

    return shard_map(shard_fn, mesh=mesh,
                     in_specs=(P(), P("clients"), P("clients")),
                     out_specs=P(), check_rep=False)(params, stacked, w)


def make_step(cfg: ServeConfig, codec_params: Optional[Pytree] = None):
    """Build the donated jitted serve step: state → state, one ingest
    round. Everything — pop, payload synthesis, fused decode→aggregate,
    model update, re-dispatch — is one XLA computation; the state pytree
    is donated (``donate_argnums=0``), so each round's output overwrites
    the previous round's buffers (double-buffered steady state)."""
    k = cfg.buffer_k

    def step(state: Dict[str, Any]) -> Dict[str, Any]:
        times, seqs = state["times"], state["seqs"]
        popped_t, idx = pop_k_device(times, seqs, k)
        clock = jnp.maximum(state["clock"], popped_t[-1])

        # staleness-discounted FedBuff weights, normalized on device
        stale = (state["version"] - state["versions"][idx]).astype(
            jnp.float32)
        w = (1.0 + stale) ** (-cfg.staleness_power)
        w = w / jnp.sum(w)

        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                                 state["next_seq"])
        k_pay, k_lat = jax.random.split(key)
        stacked = synthetic_payloads(cfg.spec, codec_params, k, k_pay)
        mean = _decode_aggregate(cfg, codec_params, stacked, w)
        global_flat = state["global_flat"] + cfg.server_lr * mean

        # re-dispatch exactly the drained cohort with the new model
        lat = _latency(cfg, k_lat, idx)
        new_seqs = state["next_seq"] + jnp.arange(k, dtype=jnp.int32)
        return {
            "times": times.at[idx].set(clock + lat),
            "seqs": seqs.at[idx].set(new_seqs),
            "versions": state["versions"].at[idx].set(
                state["version"] + 1),
            "global_flat": global_flat,
            "clock": clock,
            "version": state["version"] + 1,
            "next_seq": state["next_seq"] + jnp.int32(k),
        }

    return jax.jit(step, donate_argnums=0)


def round_bytes(cfg: ServeConfig,
                codec_params: Optional[Pytree] = None) -> int:
    """Uplink bytes one ingest round consumes: K encoded payloads at the
    spec's static wire price (``codec.wire_bytes`` — the same pricing the
    rate controllers plan with)."""
    return cfg.buffer_k * codec.wire_bytes(cfg.spec, codec_params)


def run_serve(cfg: ServeConfig, n_rounds: int,
              codec_params: Optional[Pytree] = None,
              warmup: int = 1,
              global_flat: Optional[jax.Array] = None
              ) -> Tuple[Dict[str, Any], Dict[str, float]]:
    """Drive the serve loop for ``n_rounds`` timed rounds (after
    ``warmup`` untimed ones that absorb compilation) and report sustained
    throughput. Returns ``(final_state, report)`` with ``rounds_per_sec``,
    ``bytes_per_sec`` (ingested uplink), and ``us_per_round``.

    Donation discipline: ``state`` is rebound to the step's return value
    every round — the donated argument is dead the moment the call is
    issued, and XLA recycles its buffers for the next generation."""
    step = make_step(cfg, codec_params)
    state = init_state(cfg, codec_params, global_flat=global_flat)
    for _ in range(max(warmup, 1)):
        state = step(state)
    jax.block_until_ready(state["global_flat"])
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        state = step(state)
    jax.block_until_ready(state["global_flat"])
    dt = time.perf_counter() - t0
    per_round = round_bytes(cfg, codec_params)
    report = {
        "rounds_per_sec": n_rounds / dt,
        "bytes_per_sec": n_rounds * per_round / dt,
        "us_per_round": dt / n_rounds * 1e6,
        "round_bytes": float(per_round),
        "sim_time": float(state["clock"]),
    }
    return state, report

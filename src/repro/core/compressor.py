"""Weight-update compressors: the collaborator→aggregator codec API.

``Compressor.encode`` runs on the collaborator (the paper's encoder side),
``Compressor.decode`` on the aggregator (decoder side). All compressors are
pytree→pytree: they flatten the update with ``ravel_pytree``, compress the
flat vector, and unflatten on decode, so they work for every architecture in
the zoo (§Arch-applicability in DESIGN.md).

As of the jit-native codec refactor (DESIGN.md §7) these classes are thin
host-side **adapters** over ``core/codec.py``: each one contributes a static
``spec(n)`` (hashable, jit-static — shapes, bits, chunking, ``orig_len``)
plus its AE params, and delegates the actual math to the pure
``codec.encode``/``codec.decode`` functions. Payloads are dicts of
fixed-shape arrays with **no** length metadata on the wire (``orig_len`` is
spec data now), so the same payloads stack along a client axis and feed the
batched server path ``codec.decode_and_aggregate``.

Implementations:
* Identity           — baseline (no compression)
* Quantize (int8/4)  — the traditional baseline the paper cites (FedPAQ et al.)
* TopK               — DGC/STC-style magnitude sparsification baseline
* KMeans             — FedZip-style clustered quantization (device-fit codebook)
* FCAE               — paper-faithful full fully-connected AE
* ChunkedAE          — TPU-scale shared-chunk AE (DESIGN.md §3.2)
* Composed           — AE then latent quantization ("orthogonal add-on", §4.2)
* Chain              — composable stage stack (DESIGN.md §13): sub-compressors
  chained through ``codec.ChainSpec``, optionally entropy-priced
* Partitioned        — per-layer codec partitions: one sub-compressor per
  named leaf group of the model pytree (DESIGN.md §10)

Every compressor reports ``compressed_bytes``/``original_bytes`` so the
federated runtime can account the savings ratio (paper Eq. 4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.configs.paper import AEConfig
from repro.core import autoencoder as ae
from repro.core import codec

Pytree = Any


def tree_bytes(tree: Pytree) -> int:
    """Wire size of a pytree payload: sum of leaf nbytes. Used for both
    uplink (compressed payloads) and downlink (global-model broadcast)
    accounting in the scheduler layer (DESIGN.md §6)."""
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


_nbytes = tree_bytes


def codec_stats(flat: jax.Array, payload: Pytree,
                spec: Optional[codec.CodecSpec] = None) -> Dict[str, float]:
    """The Eq.-4 byte accounting for one encoded update — the single
    definition shared by ``Compressor.roundtrip`` and the scheduler's
    ``_encode_local`` (so RoundRecord ratios and roundtrip ratios can never
    diverge). With ``spec`` the measured-bytes channel (DESIGN.md §13.3) is
    populated too: equal to ``compressed_bytes`` for shape-static specs, the
    empirical entropy-coded price for ``EntropySpec``-terminated chains."""
    stats = {
        "original_bytes": float(flat.size * flat.dtype.itemsize),
        "compressed_bytes": float(tree_bytes(payload)),
    }
    stats["compression_ratio"] = (
        stats["original_bytes"] / max(stats["compressed_bytes"], 1.0))
    stats["measured_bytes"] = stats["compressed_bytes"]
    if spec is not None and not codec.is_shape_static(spec):
        stats["measured_bytes"] = float(codec.measured_bytes(spec, payload))
    return stats


# ---------------------------------------------------------------------------
# Error feedback (DGC/EF-SGD style, beyond paper): the per-client residual is
# *compressor state* owned by the scheduler's ClientState so it survives
# rounds where the client is not sampled (DESIGN.md §6.3).
# ---------------------------------------------------------------------------
def ef_compensate(payload: Pytree, residual: Optional[Pytree]) -> Pytree:
    """Fold the previous round's reconstruction residual into this payload."""
    if residual is None:
        return payload
    return jax.tree_util.tree_map(lambda u, res: u + res, payload, residual)


def ef_residual(payload: Pytree, decoded: Pytree) -> Pytree:
    """What the codec lost this round: kept locally, re-sent next round."""
    return jax.tree_util.tree_map(lambda u, d: u - d, payload, decoded)


class Compressor:
    """Base codec adapter over update pytrees.

    Subclasses implement :meth:`spec` (static codec spec for an ``n``-element
    flat update) and optionally :meth:`codec_params`; encode/decode/roundtrip
    are inherited and delegate to the pure functions in ``core/codec.py``."""

    name = "base"

    def spec(self, n: int) -> codec.CodecSpec:
        """The static (hashable, jit-static) spec for an n-element update."""
        raise NotImplementedError

    def codec_params(self) -> Optional[Any]:
        """AE parameter pytree for the AE codecs; None for pointwise ones."""
        return None

    def ae_compressor(self) -> Optional["Compressor"]:
        """The AE-backed compressor inside this adapter: ``self`` for the AE
        codecs, the wrapped inner one for ``Composed``, ``None`` for the
        pointwise codecs. The AE lifecycle (DESIGN.md §8) uses this to find
        the refittable params behind whatever adapter a client runs.
        ``PartitionedCompressor`` returns None here — it may hold *several*
        AE-backed groups; use :func:`partitioned` + its per-group subs."""
        return None

    def set_codec_params(self, restored: Any) -> None:
        """Restore checkpointed codec params into this adapter (the inverse
        of :meth:`codec_params` for AE-backed codecs; no-op payload for
        pointwise ones). ``PartitionedCompressor`` fans the per-group dict
        out to its sub-compressors."""
        if restored is not None:
            self.ae_compressor().params = restored

    def encode(self, update: Pytree) -> Pytree:
        flat, _ = ravel_pytree(update)
        spec = self.spec(flat.size)
        self._spec = spec                     # remembered for decode()
        return codec.encode(spec, self.codec_params(), flat)

    def decode(self, payload: Pytree, unravel: Callable) -> Pytree:
        spec = getattr(self, "_spec", None)
        assert spec is not None, (
            "decode() before encode(): the wire payload carries no length "
            "metadata, so the static spec must come from this adapter's "
            "last encode (or use codec.decode(spec, ...) directly)")
        return unravel(codec.decode(spec, self.codec_params(), payload))

    def roundtrip(self, update: Pytree) -> Tuple[Pytree, Dict[str, float]]:
        flat, unravel = ravel_pytree(update)
        payload = self.encode(update)
        decoded = self.decode(payload, unravel)
        return decoded, codec_stats(flat, payload, spec=self._spec)


class IdentityCompressor(Compressor):
    name = "identity"

    def spec(self, n: int) -> codec.IdentitySpec:
        return codec.IdentitySpec(size=n)


@dataclasses.dataclass
class QuantizeCompressor(Compressor):
    """Blockwise absmax quantization to int8 (or packed int4)."""

    bits: int = 8
    block: int = 256
    name: str = "quantize"

    def __post_init__(self):
        self.name = f"quantize{self.bits}"

    def spec(self, n: int) -> codec.QuantizeSpec:
        return codec.QuantizeSpec(size=n, bits=self.bits, block=self.block)


@dataclasses.dataclass
class TopKCompressor(Compressor):
    """Keep the top-k magnitudes (DGC-style); ship (values, int32 indices)."""

    fraction: float = 0.01
    name: str = "topk"

    def spec(self, n: int) -> codec.TopKSpec:
        return codec.TopKSpec(size=n, k=max(1, int(n * self.fraction)))


@dataclasses.dataclass
class KMeansCompressor(Compressor):
    """FedZip-style clustered quantization: per-update k-means codebook fit
    on device at encode time; ships (codes, codebook). ``params`` is the
    optional warm-start codebook — refreshed from each encode is not needed
    (the codebook travels with the payload), but a checkpointed one seeds
    Lloyd iterations after restore."""

    k: int = 16
    iters: int = 8
    params: Any = None                      # optional {"codebook": (k,)}
    name: str = "kmeans"

    def __post_init__(self):
        self.name = f"kmeans{self.k}"

    def spec(self, n: int) -> codec.KMeansSpec:
        return codec.KMeansSpec(size=n, k=self.k, iters=self.iters)

    def codec_params(self):
        return self.params

    def set_codec_params(self, restored) -> None:
        if restored is not None:
            self.params = restored


@dataclasses.dataclass
class ChainCompressor(Compressor):
    """Composable codec stack (DESIGN.md §13): ``inner`` sub-compressors
    chained left-to-right, each stage's spec sized from the previous
    stage's carry length. ``entropy_coded=True`` appends an
    ``EntropySpec`` pricing stage, surfacing the empirical entropy-coded
    wire size on the measured-bytes channel while the shape-static plan
    price stays dense. ``codec_params()`` is a per-stage tuple (None for
    stateless stages) cached by identity so the scheduler's shared-params
    ``is`` fast-path keeps grouping chain cohorts."""

    inner: Any                              # Sequence[Compressor]
    entropy_coded: bool = False
    table_bytes_per_symbol: int = 4
    name: str = "chain"

    def __post_init__(self):
        self.inner = list(self.inner)
        assert self.inner, "ChainCompressor needs at least one stage"
        self.name = "->".join(c.name for c in self.inner)
        if self.entropy_coded:
            self.name += "+ec"

    def spec(self, n: int) -> codec.ChainSpec:
        stages = []
        size = n
        for i, comp in enumerate(self.inner):
            st = comp.spec(size)
            stages.append(st)
            if i < len(self.inner) - 1:
                size = codec.stage_out_size(st)
                if size is None:
                    raise ValueError(
                        f"{comp.name} is terminal-only and cannot precede "
                        f"{self.inner[i + 1].name} in a chain")
        if self.entropy_coded:
            stages.append(codec.EntropySpec(
                table_bytes_per_symbol=self.table_bytes_per_symbol))
        return codec.ChainSpec(tuple(stages))

    def codec_params(self):
        ps = tuple(comp.codec_params() for comp in self.inner)
        if all(p is None for p in ps):
            return None
        cached = getattr(self, "_params_cache", None)
        if (cached is not None and len(cached) == len(ps)
                and all(a is b for a, b in zip(cached, ps))):
            return cached
        self._params_cache = ps
        return ps

    def ae_compressor(self):
        for comp in self.inner:
            sub = comp.ae_compressor()
            if sub is not None:
                return sub
        return None

    def set_codec_params(self, restored) -> None:
        if restored is None:
            return
        assert len(restored) == len(self.inner), (
            f"restored chain params have {len(restored)} stages, adapter "
            f"has {len(self.inner)}")
        for comp, p in zip(self.inner, restored):
            comp.set_codec_params(p)


@dataclasses.dataclass
class FCAECompressor(Compressor):
    """Paper-faithful full FC AE: latent = the entire update's encoding."""

    params: Any
    cfg: AEConfig
    name: str = "fc_ae"

    def spec(self, n: int) -> codec.FCAESpec:
        return codec.FCAESpec(size=n, cfg=self.cfg)

    def codec_params(self):
        return self.params

    def ae_compressor(self):
        return self


@dataclasses.dataclass
class ChunkedAECompressor(Compressor):
    """Shared-chunk AE (TPU-scale). ``use_kernel=None`` (the default)
    auto-selects the Pallas kernel path from ``jax.default_backend()`` —
    TPU runs take the kernels natively, CPU/GPU take pure-jnp — with
    ``REPRO_USE_KERNEL=0|1`` as the explicit override
    (``kernels.ops.use_kernel_default``)."""

    params: Any
    cfg: ae.ChunkedAEConfig
    use_kernel: Optional[bool] = None
    name: str = "chunked_ae"

    def spec(self, n: int) -> codec.ChunkedAESpec:
        from repro.kernels.ops import use_kernel_default
        return codec.ChunkedAESpec(
            size=n, cfg=self.cfg,
            use_kernel=use_kernel_default(self.use_kernel))

    def codec_params(self):
        return self.params

    def ae_compressor(self):
        return self


@dataclasses.dataclass
class ComposedCompressor(Compressor):
    """AE latents further quantized — the paper's "orthogonal combination"
    claim (§4.2) made concrete: ratio multiplies (AE_ratio × 32/bits)."""

    inner: Compressor
    bits: int = 8
    block: int = 64
    name: str = "composed"

    def __post_init__(self):
        self.name = f"{self.inner.name}+q{self.bits}"

    def spec(self, n: int) -> codec.ComposedSpec:
        return codec.ComposedSpec(inner=self.inner.spec(n), bits=self.bits,
                                  block=self.block)

    def codec_params(self):
        return self.inner.codec_params()

    def ae_compressor(self):
        return self.inner.ae_compressor()


@dataclasses.dataclass
class PartitionedCompressor(Compressor):
    """Per-layer codec partitions (DESIGN.md §10): one sub-compressor per
    named leaf group of a frozen ``partition.PartitionMap``. ``spec(n)``
    assembles the jit-static ``partition.PartitionSpec`` from the current
    sub-compressors (so a rate-control rung switch that swaps one group's
    sub-compressor is visible on the next encode), ``codec_params()`` is
    the per-group ``{name: params_or_None}`` dict the partition codec
    functions consume. The AE lifecycle and rate controllers address the
    AE-backed groups individually via :func:`partitioned` — this adapter
    deliberately has no single ``ae_compressor()``."""

    pmap: Any                               # partition.PartitionMap
    compressors: Dict[str, Compressor]
    name: str = "partitioned"

    def __post_init__(self):
        assert set(self.compressors) == set(self.pmap.names), (
            f"sub-compressor keys {sorted(self.compressors)} != partition "
            f"groups {sorted(self.pmap.names)}")

    def spec(self, n: int):
        from repro.core import partition
        assert n == self.pmap.size, (
            f"update has {n} params but the partition map covers "
            f"{self.pmap.size}")
        subs = {name: comp.spec(self.pmap.group_size(name))
                for name, comp in self.compressors.items()}
        # sub-compressors only change on an explicit rung switch, so cache
        # the assembled (and tiling-revalidated) PartitionSpec keyed on the
        # current sub-specs — per-encode assembly cost would otherwise
        # scale with the leaf count on by_leaf partitions of large models
        key = tuple(sorted(subs.items(), key=lambda kv: kv[0]))
        cached = getattr(self, "_spec_cache", None)
        if cached is not None and cached[0] == key:
            return cached[1]
        spec = partition.make_partition_spec(self.pmap, subs)
        self._spec_cache = (key, spec)
        return spec

    def codec_params(self):
        return {name: comp.codec_params()
                for name, comp in self.compressors.items()}

    def set_codec_params(self, restored) -> None:
        if restored is None:
            return
        for name, p in restored.items():
            if p is not None:
                self.compressors[name].set_codec_params(p)

    def ae_groups(self) -> Dict[str, Compressor]:
        """The AE-backed sub-compressors, keyed by group name — what the
        lifecycle buffers/refits and the controllers refit-on-switch."""
        return {name: comp.ae_compressor()
                for name, comp in self.compressors.items()
                if comp.ae_compressor() is not None}


def partitioned(comp: Compressor) -> Optional[PartitionedCompressor]:
    """``comp`` as a :class:`PartitionedCompressor`, or None — how the
    lifecycle/rate-control layers detect per-partition clients without
    isinstance checks sprinkled everywhere."""
    return comp if isinstance(comp, PartitionedCompressor) else None

"""Weight-update compressors: the collaborator→aggregator codec API.

``Compressor.encode`` runs on the collaborator (the paper's encoder side),
``Compressor.decode`` on the aggregator (decoder side). All compressors are
pytree→pytree: they flatten the update with ``ravel_pytree``, compress the
flat vector, and unflatten on decode, so they work for every architecture in
the zoo (§Arch-applicability in DESIGN.md).

Implementations:
* Identity           — baseline (no compression)
* Quantize (int8/4)  — the traditional baseline the paper cites (FedPAQ et al.)
* TopK               — DGC/STC-style magnitude sparsification baseline
* FCAE               — paper-faithful full fully-connected AE
* ChunkedAE          — TPU-scale shared-chunk AE (DESIGN.md §3.2)
* Composed           — AE then latent quantization ("orthogonal add-on", §4.2)

Every compressor reports ``compressed_bytes``/``original_bytes`` so the
federated runtime can account the savings ratio (paper Eq. 4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.configs.paper import AEConfig
from repro.core import autoencoder as ae

Pytree = Any


def tree_bytes(tree: Pytree) -> int:
    """Wire size of a pytree payload: sum of leaf nbytes. Used for both
    uplink (compressed payloads) and downlink (global-model broadcast)
    accounting in the scheduler layer (DESIGN.md §6)."""
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


_nbytes = tree_bytes


# ---------------------------------------------------------------------------
# Error feedback (DGC/EF-SGD style, beyond paper): the per-client residual is
# *compressor state* owned by the scheduler's ClientState so it survives
# rounds where the client is not sampled (DESIGN.md §6.3).
# ---------------------------------------------------------------------------
def ef_compensate(payload: Pytree, residual: Optional[Pytree]) -> Pytree:
    """Fold the previous round's reconstruction residual into this payload."""
    if residual is None:
        return payload
    return jax.tree_util.tree_map(lambda u, res: u + res, payload, residual)


def ef_residual(payload: Pytree, decoded: Pytree) -> Pytree:
    """What the codec lost this round: kept locally, re-sent next round."""
    return jax.tree_util.tree_map(lambda u, d: u - d, payload, decoded)


class Compressor:
    """Base codec over update pytrees."""

    name = "base"

    def encode(self, update: Pytree) -> Pytree:
        raise NotImplementedError

    def decode(self, payload: Pytree, unravel: Callable) -> Pytree:
        raise NotImplementedError

    def roundtrip(self, update: Pytree) -> Tuple[Pytree, Dict[str, float]]:
        flat, unravel = ravel_pytree(update)
        payload = self.encode(update)
        decoded = self.decode(payload, unravel)
        stats = {
            "original_bytes": float(flat.size * flat.dtype.itemsize),
            "compressed_bytes": float(_nbytes(payload)),
        }
        stats["compression_ratio"] = (
            stats["original_bytes"] / max(stats["compressed_bytes"], 1.0))
        return decoded, stats


class IdentityCompressor(Compressor):
    name = "identity"

    def encode(self, update: Pytree) -> Pytree:
        flat, _ = ravel_pytree(update)
        return {"flat": flat}

    def decode(self, payload: Pytree, unravel: Callable) -> Pytree:
        return unravel(payload["flat"])


@dataclasses.dataclass
class QuantizeCompressor(Compressor):
    """Blockwise absmax quantization to int8 (or packed int4)."""

    bits: int = 8
    block: int = 256
    name: str = "quantize"

    def __post_init__(self):
        self.name = f"quantize{self.bits}"

    def encode(self, update: Pytree) -> Pytree:
        from repro.kernels import ops
        flat, _ = ravel_pytree(update)
        q, scales, orig_len = ops.quantize_blocks(flat, bits=self.bits,
                                                  block=self.block)
        return {"q": q, "scales": scales,
                "orig_len": jnp.int32(orig_len)}

    def decode(self, payload: Pytree, unravel: Callable) -> Pytree:
        from repro.kernels import ops
        flat = ops.dequantize_blocks(payload["q"], payload["scales"],
                                     bits=self.bits, block=self.block,
                                     orig_len=int(payload["orig_len"]))
        return unravel(flat)


@dataclasses.dataclass
class TopKCompressor(Compressor):
    """Keep the top-k magnitudes (DGC-style); ship (values, int32 indices)."""

    fraction: float = 0.01
    name: str = "topk"

    def encode(self, update: Pytree) -> Pytree:
        flat, _ = ravel_pytree(update)
        k = max(1, int(flat.size * self.fraction))
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        return {"values": flat[idx], "indices": idx.astype(jnp.int32),
                "size": jnp.int32(flat.size)}

    def decode(self, payload: Pytree, unravel: Callable) -> Pytree:
        flat = jnp.zeros((int(payload["size"]),), payload["values"].dtype)
        flat = flat.at[payload["indices"]].set(payload["values"])
        return unravel(flat)


@dataclasses.dataclass
class FCAECompressor(Compressor):
    """Paper-faithful full FC AE: latent = the entire update's encoding."""

    params: Any
    cfg: AEConfig
    name: str = "fc_ae"

    def encode(self, update: Pytree) -> Pytree:
        flat, _ = ravel_pytree(update)
        pad = self.cfg.input_dim - flat.size
        assert pad >= 0, (
            f"AE input_dim {self.cfg.input_dim} < update size {flat.size}")
        orig = flat.size
        if pad:
            flat = jnp.pad(flat, (0, pad))
        z = ae.fc_encode(self.params, self.cfg, flat)
        return {"z": z, "orig_len": jnp.int32(orig)}

    def decode(self, payload: Pytree, unravel: Callable) -> Pytree:
        flat = ae.fc_decode(self.params, self.cfg, payload["z"])
        return unravel(flat[:int(payload["orig_len"])])


@dataclasses.dataclass
class ChunkedAECompressor(Compressor):
    """Shared-chunk AE (TPU-scale). Uses the Pallas encode/decode kernels when
    running on TPU; pure-jnp path otherwise."""

    params: Any
    cfg: ae.ChunkedAEConfig
    use_kernel: bool = False
    name: str = "chunked_ae"

    def encode(self, update: Pytree) -> Pytree:
        flat, _ = ravel_pytree(update)
        if self.use_kernel:
            from repro.kernels import ops
            z = ops.ae_encode(self.params, self.cfg, flat)
        else:
            z = ae.chunked_encode(self.params, self.cfg, flat)
        return {"z": z, "orig_len": jnp.int32(flat.size)}

    def decode(self, payload: Pytree, unravel: Callable) -> Pytree:
        n = int(payload["orig_len"])
        if self.use_kernel:
            from repro.kernels import ops
            flat = ops.ae_decode(self.params, self.cfg, payload["z"], n)
        else:
            flat = ae.chunked_decode(self.params, self.cfg, payload["z"], n)
        return unravel(flat)


@dataclasses.dataclass
class ComposedCompressor(Compressor):
    """AE latents further quantized — the paper's "orthogonal combination"
    claim (§4.2) made concrete: ratio multiplies (AE_ratio × 32/bits)."""

    inner: Compressor
    bits: int = 8
    block: int = 64
    name: str = "composed"

    def __post_init__(self):
        self.name = f"{self.inner.name}+q{self.bits}"

    def encode(self, update: Pytree) -> Pytree:
        from repro.kernels import ops
        payload = self.inner.encode(update)
        z = payload["z"]
        q, scales, orig = ops.quantize_blocks(z.reshape(-1), bits=self.bits,
                                              block=self.block)
        out = dict(payload)
        out["z_shape"] = jnp.array(z.shape, jnp.int32)
        out["z"] = q
        out["z_scales"] = scales
        out["z_len"] = jnp.int32(orig)
        return out

    def decode(self, payload: Pytree, unravel: Callable) -> Pytree:
        from repro.kernels import ops
        z = ops.dequantize_blocks(payload["z"], payload["z_scales"],
                                  bits=self.bits, block=self.block,
                                  orig_len=int(payload["z_len"]))
        inner_payload = {k: v for k, v in payload.items()
                         if k not in ("z", "z_scales", "z_len", "z_shape")}
        inner_payload["z"] = z.reshape(tuple(int(s)
                                             for s in payload["z_shape"]))
        return self.inner.decode(inner_payload, unravel)

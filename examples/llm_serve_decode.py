"""Batched serving example: prefill a prompt batch, then decode tokens with
the KV/state cache — the same prefill/decode steps the dry-run lowers at
(32, 32768) and (128, 32768) scale, here CPU-sized.

Works for every architecture family, including attention-free (mamba2) and
hybrid (recurrentgemma) whose decode state is O(1) in context length.

Run: PYTHONPATH=src python examples/serve_decode.py --arch mamba2-2.7b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--window", type=int, default=None,
                    help="sliding-window decode (long-context mode)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    k = jax.random.PRNGKey(1)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            k, (B, cfg.encdec.n_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            k, (B, cfg.vlm.n_image_tokens, cfg.d_model))

    cache_len = S + args.new_tokens
    prefill_fn = jax.jit(lambda p, b: prefill(p, cfg, b, cache_len=cache_len,
                                              window=args.window))
    step_fn = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c,
                                                  window=args.window))

    t0 = time.time()
    logits, cache = prefill_fn(params, batch)
    print(f"== {cfg.name}: prefilled {B}x{S} in {time.time() - t0:.2f}s ==")

    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(
        jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, cache = step_fn(params, tok, cache)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(
            jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"decoded {args.new_tokens - 1} tokens/seq in {dt:.2f}s "
          f"({(args.new_tokens - 1) * B / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()

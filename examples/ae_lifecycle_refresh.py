"""AE training lifecycle demo (DESIGN.md §8): drift-triggered decoder
refresh with honest Eq. 4–6 accounting.

A 4-client federation runs the paper's §5.2 weights-payload protocol under
per-client FC autoencoders. An :class:`AELifecycle` with a refresh cadence
plus a reconstruction-drift trigger:

1. buffers each client's encoded weight vectors (``ClientState.snapshots``),
2. warm-start refits the AEs on the jit-native scan trainer — same-round
   refits share ONE ``train_autoencoder_cohort`` dispatch,
3. charges every decoder sync (initial ship + each refresh) to
   ``RoundRecord.bytes_down``/``bytes_decoder``,
4. reconciles the observed totals against the paper's savings-ratio model
   (``savings.reconcile``).

Run: PYTHONPATH=src python examples/ae_lifecycle_refresh.py
"""
import jax
import jax.numpy as jnp

from repro.configs.paper import MNIST_CLASSIFIER, AEConfig
from repro.core import (AELifecycle, FCAECompressor, FLConfig, FederatedRun,
                        SavingsModel, ae_param_count, run_prepass)
from repro.data.pipeline import (mnist_like, train_eval_split,
                                 uniform_partition)

N_CLIENTS = 4
AE_CFG = AEConfig(input_dim=15_910, encoder_hidden=(64,), latent_dim=32)


def main():
    train, ev = train_eval_split(mnist_like(0, 768), 256)
    data = uniform_partition(0, train, N_CLIENTS)

    # pre-pass: one weights dataset + AE per client (paper Fig. 2)
    comps = []
    for ci in range(N_CLIENTS):
        out = run_prepass(jax.random.PRNGKey(10 + ci), MNIST_CLASSIFIER,
                          AE_CFG, data[ci], prepass_epochs=6, ae_epochs=40)
        comps.append(FCAECompressor(out["ae_params"], AE_CFG))

    lifecycle = AELifecycle(refresh_every=3, drift_ratio=2.0,
                            min_snapshots=2, refresh_epochs=20,
                            buffer_size=8)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=7, local_epochs=1, payload="weights"),
        compressors=comps, eval_data=ev, lifecycle=lifecycle)
    hist = run.run()

    print("round  acc    bytes_up  bytes_down  decoder_share  ae_syncs")
    for r in hist:
        share = r.bytes_decoder / max(r.bytes_down, 1.0)
        print(f"{r.round:>5}  {r.global_metrics['accuracy']:.3f}  "
              f"{r.bytes_up:>8.0f}  {r.bytes_down:>10.0f}  "
              f"{share:>12.1%}  {r.ae_syncs}")

    model = SavingsModel(
        original_size=15_910, compressed_size=AE_CFG.latent_dim,
        autoencoder_size=ae_param_count(comps[0].params),
        n_decoders=N_CLIENTS)
    report = run.savings_report(model)
    print("\nEq. 4-6 reconciliation (savings.reconcile):")
    for k, v in report.items():
        print(f"  {k:>26}: {v:,.4f}")
    assert report["decoder_rel_err"] < 0.05, report
    print("\nobserved decoder traffic reconciles with Eq. 5/6 "
          f"({report['decoder_syncs']:.0f} syncs, "
          f"{report['decoder_rel_err']:.1%} structural error)")


if __name__ == "__main__":
    main()

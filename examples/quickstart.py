"""Quickstart: the paper's full pipeline in ~a minute on CPU.

1. Pre-pass round (Fig. 2): train the MNIST classifier locally, log weights
   at every epoch, train the fully-connected funnel AE on that dataset.
2. Compress the model's weight update through the encoder (Eq. 1), "ship"
   the 32-float latent, reconstruct at the aggregator (Eq. 2).
3. Validation model (§5.1): accuracy with AE-predicted weights vs original.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.paper import MNIST_AE, MNIST_CLASSIFIER
from repro.core import (FCAECompressor, fc_reconstruct, run_prepass,
                        validation_model_curve)
from repro.data.pipeline import mnist_like


def main():
    print("== FedAE quickstart: MNIST classifier, 15,910 params ==")
    data = mnist_like(seed=0, n=768)
    out = run_prepass(jax.random.PRNGKey(0), MNIST_CLASSIFIER, MNIST_AE,
                      data, prepass_epochs=10, ae_epochs=80)
    hist = out["ae_history"]
    print(f"pre-pass: {out['weights_dataset'].shape[0]} weight snapshots, "
          f"AE loss {hist['loss'][0]:.4f} -> {hist['loss'][-1]:.4f}, "
          f"AE accuracy {hist['accuracy'][-1]:.3f} "
          f"(val {hist['val_accuracy'][-1]:.3f})")

    comp = FCAECompressor(out["ae_params"], MNIST_AE)
    decoded, stats = comp.roundtrip(out["model_params"])
    print(f"compression: {stats['original_bytes']:.0f} B -> "
          f"{stats['compressed_bytes']:.0f} B "
          f"= {stats['compression_ratio']:.0f}x (paper: ~500x)")

    curve = validation_model_curve(
        MNIST_CLASSIFIER, out["weights_dataset"],
        lambda w: fc_reconstruct(out["ae_params"], MNIST_AE, w), data)
    print("validation model (orig vs AE-predicted accuracy per epoch):")
    for i, (o, p) in enumerate(zip(curve["original_acc"],
                                   curve["predicted_acc"])):
        print(f"  epoch {i:2d}: {o:.3f} vs {p:.3f}")


if __name__ == "__main__":
    main()

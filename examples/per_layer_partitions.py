"""Per-layer codec partitions demo (DESIGN.md §10): one codec per model
layer, grouped fused aggregation, per-partition decoder accounting.

A 3-client federation on the paper's MNIST MLP, partitioned by layer:
``dense0`` (15,700 params — the bulk) rides a per-client FC autoencoder,
``dense1`` (the 210-param head, where reconstruction error hurts logits
directly) rides int8 quantization. The run shows:

1. the per-partition wire price list (``wire_bytes_by_group``) and the
   mixed compression ratio on the wire,
2. the AE lifecycle shipping/refreshing ONLY the AE-backed group's decoder
   (``ae_syncs`` entries are ``(client, group)`` lanes),
3. ``savings.reconcile`` with a ``{group: SavingsModel}`` mapping — the
   Eq. 5 Cost term summed per partition's own decoder ships.

The per-client AEs start at a random init (no pre-pass, to keep the demo
fast), so early rounds sit near chance until the cadence refit at round 3
fits the decoders to the real weights distribution — accuracy then jumps
to ~0.96, the §8 lifecycle story in miniature.

Run: PYTHONPATH=src python examples/per_layer_partitions.py
"""
import jax

from repro.configs.paper import MNIST_CLASSIFIER, AEConfig
from repro.core import (AELifecycle, FCAECompressor, FLConfig, FederatedRun,
                        PartitionedCompressor, QuantizeCompressor,
                        SavingsModel, by_layer_partition,
                        wire_bytes_by_group)
from repro.core import autoencoder as ae
from repro.data.pipeline import (mnist_like, train_eval_split,
                                 uniform_partition)
from repro.models.classifiers import init_classifier

N_CLIENTS = 3


def main():
    template = init_classifier(jax.random.PRNGKey(0), MNIST_CLASSIFIER)
    pmap = by_layer_partition(template)
    d0 = pmap.group_size("dense0")
    ae_cfg = AEConfig(input_dim=d0, encoder_hidden=(64,), latent_dim=32)
    print(f"partition groups: { {n: pmap.group_size(n) for n in pmap.names} }")

    train, ev = train_eval_split(mnist_like(0, 768), 256)
    data = uniform_partition(0, train, N_CLIENTS)
    comps = [PartitionedCompressor(pmap, {
        "dense0": FCAECompressor(
            ae.init_fc_ae(jax.random.PRNGKey(10 + ci), ae_cfg), ae_cfg),
        "dense1": QuantizeCompressor(bits=8),
    }) for ci in range(N_CLIENTS)]
    prices = wire_bytes_by_group(comps[0].spec(pmap.size),
                                 comps[0].codec_params())
    print(f"per-partition uplink bytes: {prices} "
          f"(raw: { {n: 4 * pmap.group_size(n) for n in pmap.names} })")

    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=6, local_epochs=2, payload="weights"),
        compressors=comps, eval_data=ev,
        lifecycle=AELifecycle(refresh_every=3, min_snapshots=2,
                              refresh_epochs=150, batch_size=4))
    hist = run.run()
    for r in hist:
        print(f"round {r.round}: acc={r.global_metrics['accuracy']:.3f} "
              f"up={r.bytes_up / 1e3:.1f}kB (x{r.compression_ratio:.0f}) "
              f"decoder={r.bytes_decoder / 1e6:.2f}MB syncs={r.ae_syncs}")

    models = {
        "dense0": SavingsModel(
            original_size=d0, compressed_size=ae_cfg.latent_dim,
            autoencoder_size=ae_cfg.n_params, n_decoders=N_CLIENTS),
        "dense1": SavingsModel(
            original_size=pmap.group_size("dense1"),
            compressed_size=pmap.group_size("dense1") // 4,  # int8 + scales
            autoencoder_size=0, n_decoders=0),
    }
    report = run.savings_report(models)
    print("Eq. 4-6 reconciliation (per-partition decoder ships):")
    for k, v in report.items():
        print(f"  {k}: {v:.4g}")
    assert report["decoder_rel_err"] < 0.01, "structural gap bound blown"


if __name__ == "__main__":
    main()

"""Adaptive rate control demo (DESIGN.md §9): per-client dynamic codec
selection on a distortion target, with honest rung-switch accounting.

A 3-client federation runs the paper's §5.2 weights-payload protocol over a
two-rung FC-AE ladder (latent 32 → cheap, latent 128 → accurate). Each
client's rung AEs are pre-pass trained (paper Fig. 2, once per rung). A
:class:`DistortionTarget` controller then walks every client toward the
cheapest rung whose observed post-EF reconstruction error stays under the
target:

1. the post-EF encode distribution is buffered per client
   (``ClientState.snapshots``) and each round's rung error is measured on
   the newest snapshot,
2. rung switches are decided at end of round (effective next round, once
   the server has the new decoder), refitting the switched-to AE on the
   snapshot buffer through the lifecycle cohort path,
3. every decoder ship — initial rung ships and switch re-ships alike — is
   charged to ``RoundRecord.bytes_down``/``bytes_decoder``, so the Eq. 4–6
   reconciliation (``savings.reconcile``) stays honest under rung churn,
4. heterogeneous-rung cohorts are grouped by spec server-side and each
   group still takes the fused decode→aggregate path (DESIGN.md §9.2),
5. the same ladder then runs under the Lagrangian :class:`RDBudget`
   water-filler (DESIGN.md §15): distortion probed at every rung in one
   batched dispatch, curves hull-pruned, λ swept until marginal
   distortion per byte is equalized under the shared uplink budget.

Run: PYTHONPATH=src python examples/adaptive_rate_control.py
"""
import jax

from repro.configs.paper import MNIST_CLASSIFIER, AEConfig
from repro.core import (DistortionTarget, FLConfig, FederatedRun,
                        RDBudget, SavingsModel, ae_param_count,
                        fc_ae_ladder, run_prepass, train_autoencoder)
from repro.data.pipeline import (dirichlet_partition, mnist_like,
                                 train_eval_split)
from repro.models.classifiers import init_classifier

N_CLIENTS = 3
P = 15_910                         # MNIST classifier param count
LATENTS = (32, 128)
# hidden ≥ widest latent, or the hidden layer caps every rung at the same
# effective capacity and rung fidelity stops ordering (DESIGN.md §15.6)
HIDDEN = (128,)


def main():
    train, ev = train_eval_split(mnist_like(0, 768), 128)
    data = dirichlet_partition(0, train, N_CLIENTS, alpha=1.0,
                               min_per_client=32)

    # pre-pass per client, then every ladder rung's AE trained on the same
    # weights dataset (paper Fig. 2, per rung). The pre-pass starts from
    # the SAME initial global params the federated runs below init with
    # (FLConfig.seed) — an AE trained on a foreign init's trajectory
    # prices a weight basin the run never visits (DESIGN.md §15.6)
    init0 = init_classifier(jax.random.PRNGKey(FLConfig().seed),
                            MNIST_CLASSIFIER)
    params = []
    for ci in range(N_CLIENTS):
        out = run_prepass(jax.random.PRNGKey(10 + ci), MNIST_CLASSIFIER,
                          AEConfig(input_dim=P, encoder_hidden=HIDDEN,
                                   latent_dim=LATENTS[0]),
                          data[ci], prepass_epochs=8, ae_epochs=1,
                          init_params=init0)
        row = []
        for latent in LATENTS:
            cfg = AEConfig(input_dim=P, encoder_hidden=HIDDEN,
                           latent_dim=latent)
            p, _ = train_autoencoder(jax.random.PRNGKey(100 + ci), cfg,
                                     out["weights_dataset"], epochs=200)
            row.append(p)
        params.append(row)

    ladder = fc_ae_ladder(N_CLIENTS, P, latent_dims=LATENTS, hidden=HIDDEN,
                          params=params)
    rc = DistortionTarget(ladder=ladder, target=0.10, margin=0.5,
                          cooldown=2, min_snapshots=2, refit_epochs=30,
                          refit_batch=4)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=6, local_epochs=2, payload="weights"),
        eval_data=ev, ratecontrol=rc)
    hist = run.run()

    print("round  acc    bytes_up  bytes_decoder  switches       rungs")
    for r in hist:
        print(f"{r.round:>5}  {r.global_metrics['accuracy']:.3f}  "
              f"{r.bytes_up:>8.0f}  {r.bytes_decoder:>13.0f}  "
              f"{str(r.spec_switches):>12}  "
              f"{[rc.rung_of(ci) for ci in range(N_CLIENTS)]}")
    assert all(r.controller == "distortion_target" for r in hist)
    assert any(r.spec_switches for r in hist), \
        "the demo should actually walk the ladder"

    # Eq. 4-6 reconciliation, rung-switch decoder re-ships included: the
    # ladder shares its hidden stack, so the per-rung decoder sizes sit
    # within the documented structural gap of the Eq. 6 idealization
    mean_ae = sum(ae_param_count(ladder[0][k].params)
                  for k in range(len(LATENTS))) // len(LATENTS)
    model = SavingsModel(
        original_size=P, compressed_size=LATENTS[0],
        autoencoder_size=mean_ae, n_decoders=N_CLIENTS)
    report = run.savings_report(model)
    print("\nEq. 4-6 reconciliation (savings.reconcile):")
    for k, v in report.items():
        print(f"  {k:>26}: {v:,.4f}")
    assert report["decoder_rel_err"] < 0.05, report
    print(f"\n{report['decoder_syncs']:.0f} decoder ships (initial + rung "
          f"switches) reconcile with Eq. 5/6 at "
          f"{report['decoder_rel_err']:.1%} error")

    # --- the same ladder under Lagrangian water-filling (DESIGN.md §15)
    # budget: the all-cheapest floor plus one rung upgrade's worth of
    # marginal uplink — the λ sweep decides WHICH client converts that
    # headroom into the most distortion reduction per byte
    budget = N_CLIENTS * LATENTS[0] * 4.0 + (LATENTS[1] - LATENTS[0]) * 4.0
    rd = RDBudget(ladder=fc_ae_ladder(N_CLIENTS, P, latent_dims=LATENTS,
                                      hidden=HIDDEN, params=params),
                  budget=budget, cooldown=2, min_snapshots=2,
                  refit_epochs=30, refit_batch=4)
    run_rd = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=6, local_epochs=2, payload="weights"),
        eval_data=ev, ratecontrol=rd)
    hist_rd = run_rd.run()
    lam = dict(rd.lambda_trace)
    print(f"\nRDBudget at {budget:.0f} B/round shared uplink budget:")
    print("round  acc    bytes_up   lambda*        rungs")
    for r in hist_rd:
        lam_s = f"{lam[r.round]:.3e}" if lam.get(r.round) else "-"
        print(f"{r.round:>5}  {r.global_metrics['accuracy']:.3f}  "
              f"{r.bytes_up:>8.0f}  {lam_s:>9}  "
              f"{[rd.rung_of(ci) for ci in range(N_CLIENTS)]}")
    assert all(r.controller == "rd_budget" for r in hist_rd)
    # the plan binds the full sync cohort, so realized per-round uplink
    # never exceeds the budget
    assert all(r.bytes_up <= budget for r in hist_rd), \
        [(r.round, r.bytes_up) for r in hist_rd]
    assert len(rd.lambda_trace) == len(hist_rd)


if __name__ == "__main__":
    main()

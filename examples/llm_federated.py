"""Federated delta fine-tuning of a real ``configs/`` transformer through
the full ``FederatedRun`` stack (DESIGN.md §14) — the paper's "one AE per
layer" claim exercised at transformer shapes instead of toy MLPs.

A small federation fine-tunes a CPU-reduced zoo model (default
``llama3-8b``) with ``LMDeltaTask``: each client trains on its own token
shard and ships the post-error-feedback weight *delta* through the codec
stack. Three scenarios build the accuracy-vs-uplink table:

* ``identity`` — uncompressed deltas (the accuracy ceiling),
* ``q8``       — flat int8 quantization,
* ``role-ae``  — ``by_role_partition``: the bulk roles (embedding /
  attention / MLP) each ride a per-client chunked AE on the grouped
  Pallas launch (``FLConfig(use_grouped_kernel=True)``), the tiny norm
  vectors ride int8; the ``AELifecycle`` ships and refits each
  ``(client, role)`` decoder lane and every ship is reconciled against
  the paper's Eq. 4-6 within the documented ~1% structural gap.

Run: PYTHONPATH=src python examples/llm_federated.py [--arch llama3-8b]
"""
import argparse

import jax

from repro.configs import get_config
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import (AELifecycle, ChunkedAECompressor, ChunkedAEConfig,
                        FLConfig, FederatedRun, IdentityCompressor,
                        LMDeltaTask, PartitionedCompressor,
                        QuantizeCompressor, SavingsModel, ae_param_count,
                        by_role_partition, init_chunked_ae, partition,
                        train_autoencoder, wire_bytes_by_group)
from repro.core import autoencoder as ae_lib
from repro.data.pipeline import synthetic_lm_batch

AE_ROLES = ("embedding", "attention", "mlp")


def prepass_role_aes(args, cfg, pmap, ae_cfg, shards, fl):
    """The paper's pre-pass (§5.2) at transformer shapes: each client runs
    one local round from the shared init, and each AE role's chunked delta
    rows become that client's AE training set — so the codecs meet the
    actual delta distribution from round 0 instead of a random init."""
    task = LMDeltaTask(cfg)
    global_params = task.init_params(jax.random.PRNGKey(fl.seed))
    flat0 = ravel_pytree(global_params)[0]
    aes = []
    for ci in range(args.clients):
        local, _ = task.local_update(global_params, shards[ci], fl,
                                     seed=fl.seed * 997, anchor=global_params)
        delta = ravel_pytree(local)[0] - flat0
        fit = {}
        for role in AE_ROLES:
            seg = partition.gather(pmap.slices_of(role), delta)
            rows = ae_lib.chunk_vector(seg, ae_cfg.chunk_size)[0]
            params, _ = train_autoencoder(
                jax.random.PRNGKey(100 + ci), ae_cfg.as_fc(), rows,
                kind="fc", epochs=40, batch_size=64, lr=3e-3,
                init=init_chunked_ae(jax.random.PRNGKey(100 + ci), ae_cfg))
            fit[role] = params
        aes.append(fit)
    return aes


def make_run(args, cfg, scenario, pmap, ae_cfg):
    task = LMDeltaTask(cfg)
    shards = [synthetic_lm_batch(seed=10 + ci, vocab_size=cfg.vocab_size,
                                 batch=args.seqs, seq_len=args.seq)
              for ci in range(args.clients)]
    ev = synthetic_lm_batch(seed=99, vocab_size=cfg.vocab_size,
                            batch=args.seqs, seq_len=args.seq)
    # error feedback is what makes lossy delta codecs converge here: adam
    # deltas are near-white per coordinate, so a single AE pass loses most
    # of the signal — the residual carries it into the next round instead
    # of dropping it (role-ae descends monotonically; without EF it stalls)
    fl = FLConfig(n_rounds=args.rounds, local_epochs=args.local_epochs,
                  lr=1e-3, batch_size=args.batch,
                  payload="update", error_feedback=True, seed=0,
                  use_grouped_kernel=(scenario == "role-ae"))
    lifecycle = None
    if scenario == "identity":
        comps = [IdentityCompressor() for _ in range(args.clients)]
    elif scenario == "q8":
        comps = [QuantizeCompressor(bits=8) for _ in range(args.clients)]
    else:                                    # role-ae
        aes = prepass_role_aes(args, cfg, pmap, ae_cfg, shards, fl)
        comps = [PartitionedCompressor(pmap, dict(
            {role: ChunkedAECompressor(aes[ci][role], ae_cfg,
                                       use_kernel=True)
             for role in AE_ROLES},
            norm=QuantizeCompressor(bits=8))) for ci in range(args.clients)]
        lifecycle = AELifecycle(refresh_every=2, min_snapshots=2,
                                refresh_epochs=20, batch_size=32, lr=3e-3)
    return FederatedRun(task, shards, fl, compressors=comps, eval_data=ev,
                        lifecycle=lifecycle), comps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--seqs", type=int, default=8, help="sequences/client")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--local-epochs", type=int, default=3)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    ae_cfg = ChunkedAEConfig(chunk_size=256, hidden=(64,), latent_chunk=8)
    template = LMDeltaTask(cfg).init_params(jax.random.PRNGKey(0))
    pmap = by_role_partition(template)
    n_params = pmap.size
    print(f"== federated {cfg.name}: {n_params:,} params, "
          f"{args.clients} clients x {args.rounds} rounds ==")
    print("role partition:",
          {n: pmap.group_size(n) for n in pmap.names})

    table = []
    for scenario in ("identity", "q8", "role-ae"):
        run, comps = make_run(args, cfg, scenario, pmap, ae_cfg)
        if scenario == "role-ae":
            prices = wire_bytes_by_group(comps[0].spec(pmap.size),
                                         comps[0].codec_params())
            print(f"\n[{scenario}] per-role uplink bytes: {prices}")
        hist = run.run()
        for r in hist:
            print(f"[{scenario}] round {r.round}: "
                  f"loss={r.global_metrics['ce_loss']:.4f} "
                  f"acc={r.global_metrics['accuracy']:.3f} "
                  f"up={r.bytes_up / 1e3:.1f}kB (x{r.compression_ratio:.1f})"
                  + (f" decoder={r.bytes_decoder / 1e6:.2f}MB"
                     if r.bytes_decoder else ""))
        tot = run.total_bytes()
        last = hist[-1]
        table.append((scenario, last.global_metrics["ce_loss"],
                      last.global_metrics["accuracy"], tot["bytes_up"],
                      tot["effective_ratio"], tot["bytes_decoder"]))

        if scenario == "role-ae":
            # Eq. 4-6 reconciliation: each AE role's decoder ships priced
            # by its own SavingsModel; the chunked AE is shared-weights so
            # every role carries the same 256->8 autoencoder
            ae_size = ae_param_count(init_chunked_ae(
                jax.random.PRNGKey(0), ae_cfg))
            models = {}
            for name in pmap.names:
                gs = pmap.group_size(name)
                if name in AE_ROLES:
                    n_chunks = -(-gs // ae_cfg.chunk_size)
                    models[name] = SavingsModel(
                        original_size=gs,
                        compressed_size=n_chunks * ae_cfg.latent_chunk,
                        autoencoder_size=ae_size, n_decoders=args.clients)
                else:
                    models[name] = SavingsModel(
                        original_size=gs, compressed_size=gs // 4,
                        autoencoder_size=0, n_decoders=0)
            report = run.savings_report(models)
            print("Eq. 4-6 reconciliation (per-role decoder ships):")
            for k, v in report.items():
                print(f"  {k}: {v:.4g}")
            assert report["decoder_rel_err"] < 0.01, \
                "structural gap bound blown"

    print("\naccuracy vs uplink:")
    print(f"{'scenario':<10} {'ce_loss':>8} {'acc':>6} {'up_MB':>8} "
          f"{'ratio':>7} {'decoder_MB':>11}")
    for name, loss, acc, up, ratio, dec in table:
        print(f"{name:<10} {loss:>8.4f} {acc:>6.3f} {up / 1e6:>8.3f} "
              f"{ratio:>7.1f} {dec / 1e6:>11.2f}")


if __name__ == "__main__":
    main()

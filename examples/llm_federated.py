"""The paper's technique at LLM scale (CPU-reduced): federated training of a
transformer where each "pod" ships chunked-AE-compressed updates.

This drives the SAME ``fl_round_step`` that the 512-chip multi-pod dry-run
compiles, on a degenerate 1-device (pod=1, data=1, model=1) mesh, and
reports what fraction of update bytes would cross the pod axis.

Run: PYTHONPATH=src python examples/llm_federated.py [--steps 20]
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae
from repro.core.distributed import build_fl_round_step, compressed_fraction
from repro.data.pipeline import synthetic_lm_batch
from repro.models import init_params, param_count
from repro.models import sharding as shard_lib
from repro.optim.optimizers import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(cfg, learning_rate=1e-3)
    ae_cfg = ChunkedAEConfig(chunk_size=256, hidden=(64,), latent_chunk=8)
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    shape = ShapeConfig("example", args.seq, args.batch, "train")

    params = init_params(jax.random.PRNGKey(0), cfg)
    frac = compressed_fraction(params, ae_cfg)
    print(f"== federated LLM training: {cfg.name}, "
          f"{param_count(params):,} params ==")
    print(f"chunked AE {ae_cfg.chunk_size}->{ae_cfg.latent_chunk}: "
          f"cross-pod traffic = {frac * 100:.2f}% of a full all-reduce "
          f"({1 / frac:.0f}x reduction)")

    bundle = build_fl_round_step(cfg, shape, mesh, ae_cfg)
    ae_params = init_chunked_ae(jax.random.PRNGKey(1), ae_cfg)
    opt = make_optimizer(cfg.optimizer, cfg.learning_rate,
                         grad_clip=cfg.grad_clip,
                         weight_decay=cfg.weight_decay)
    opt_state = opt.init(params)

    with mesh:
        step_fn = jax.jit(
            bundle.fn,
            in_shardings=shard_lib.named(mesh, bundle.in_shardings),
            out_shardings=shard_lib.named(mesh, bundle.out_shardings))
        t0 = time.time()
        for i in range(args.steps):
            batch = synthetic_lm_batch(i, cfg.vocab_size, args.batch,
                                       args.seq)
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 ae_params, batch)
            print(f"round {i:3d}: loss={float(metrics['loss']):.4f} "
                  f"acc={float(metrics['accuracy']):.3f}", flush=True)
        print(f"avg {(time.time() - t0) / args.steps:.2f}s/round")


if __name__ == "__main__":
    main()

"""Paper §5.2 (Figs. 8/9): two-collaborator FL with color imbalance.

Collaborator 0 trains on color images, collaborator 1 on grayscale. Updates
are AE-compressed every communication round; the sawtooth accuracy/loss
pattern (dip after each aggregation) shows federation is really happening
while the pipe carries only latents.

``--stacks`` runs the composable-codec-stack comparison instead
(DESIGN.md §13): q8 vs topk→q8 vs topk→AE→q8 on a Dirichlet label-skew
split, printing an accuracy-vs-uplink table — the FedZip-direction
sparsify-then-compress stacks trade accuracy for steep uplink cuts.

Run: PYTHONPATH=src python examples/fl_color_imbalance.py [--rounds N]
     PYTHONPATH=src python examples/fl_color_imbalance.py --stacks
"""
import argparse

import jax

from repro.configs.paper import CIFAR_CLASSIFIER, cifar_ae_for
from repro.core import (ChainCompressor, ChunkedAECompressor,
                        ChunkedAEConfig, FCAECompressor, FLConfig,
                        FederatedRun, QuantizeCompressor, TopKCompressor,
                        init_chunked_ae, run_prepass)
from repro.data.pipeline import (cifar_like, color_imbalance_split,
                                 dirichlet_partition, train_eval_split)
from repro.models.classifiers import init_classifier, n_params


def run_stacks(args):
    """Codec-stack comparison on a Dirichlet non-IID split: the same
    federation under three uplink codecs — blockwise q8, FedZip-style
    topk→q8, and the paper-direction topk→AE→q8 chain."""
    n_clients = 4
    train, eval_data = train_eval_split(
        cifar_like(0, args.n * n_clients), max(32, args.n // 2))
    datasets = dirichlet_partition(0, train, n_clients, alpha=0.5,
                                   min_per_client=8)
    P = n_params(init_classifier(jax.random.PRNGKey(0), CIFAR_CLASSIFIER))
    ccfg = ChunkedAEConfig(chunk_size=256, hidden=(64,), latent_chunk=16)
    ae_params = init_chunked_ae(jax.random.PRNGKey(1), ccfg)
    print(f"== codec stacks on Dirichlet(0.5) split, {n_clients} clients, "
          f"CIFAR-CNN {P} params ==")

    def stacks():
        return {
            "q8": lambda: QuantizeCompressor(bits=8),
            "topk->q8": lambda: ChainCompressor(
                [TopKCompressor(fraction=0.1),
                 QuantizeCompressor(bits=8, block=64)]),
            "topk->ae->q8": lambda: ChainCompressor(
                [TopKCompressor(fraction=0.05),
                 ChunkedAECompressor(ae_params, ccfg),
                 QuantizeCompressor(bits=8, block=64)]),
        }

    rows = []
    for name, mk in stacks().items():
        run = FederatedRun(
            CIFAR_CLASSIFIER, datasets,
            FLConfig(n_rounds=args.rounds, local_epochs=args.local_epochs,
                     payload="update", error_feedback=True),
            compressors=[mk() for _ in range(n_clients)],
            eval_data=eval_data)
        hist = run.run()
        totals = run.total_bytes()
        rows.append((name, hist[-1].global_metrics["accuracy"],
                     totals["bytes_up"], totals["effective_ratio"]))

    print(f"\n{'stack':>14} {'final_acc':>10} {'uplink_bytes':>13} "
          f"{'ratio':>7}")
    for name, acc, up, ratio in rows:
        print(f"{name:>14} {acc:>10.3f} {up:>13.3e} {ratio:>6.0f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--n", type=int, default=256, help="samples/collab")
    ap.add_argument("--stacks", action="store_true",
                    help="codec-stack comparison on a Dirichlet split")
    args = ap.parse_args()
    if args.stacks:
        run_stacks(args)
        return

    P = n_params(init_classifier(jax.random.PRNGKey(0), CIFAR_CLASSIFIER))
    ae_cfg = cifar_ae_for(P)
    print(f"== 2-collaborator FL, CIFAR-CNN {P} params, "
          f"AE {ae_cfg.n_params} params, {ae_cfg.compression_ratio:.0f}x ==")

    datasets, eval_data = color_imbalance_split(0, args.n)
    comps = []
    for ci, d in enumerate(datasets):
        kind = "color" if ci == 0 else "grayscale"
        print(f"pre-pass for collaborator {ci} ({kind}) ...")
        out = run_prepass(jax.random.PRNGKey(10 + ci), CIFAR_CLASSIFIER,
                          ae_cfg, d, prepass_epochs=5, ae_epochs=6)
        comps.append(FCAECompressor(out["ae_params"], ae_cfg))

    run = FederatedRun(
        CIFAR_CLASSIFIER, datasets,
        FLConfig(n_rounds=args.rounds, local_epochs=args.local_epochs,
                 payload="weights"),    # paper §5.2: converged weights
        compressors=comps, eval_data=eval_data)

    def progress(rec):
        cacc = [m.get("accuracy", 0.0) for m in rec.collab_metrics]
        print(f"round {rec.round:3d}: global_acc="
              f"{rec.global_metrics['accuracy']:.3f} "
              f"collab_acc={[f'{a:.3f}' for a in cacc]} "
              f"ratio={rec.compression_ratio:.0f}x")

    run.run(progress)
    totals = run.total_bytes()
    print(f"total upstream bytes: {totals['bytes_up']:.2e} "
          f"(raw {totals['bytes_up_raw']:.2e}) -> effective "
          f"{totals['effective_ratio']:.0f}x reduction")


if __name__ == "__main__":
    main()

"""Paper §5.2 (Figs. 8/9): two-collaborator FL with color imbalance.

Collaborator 0 trains on color images, collaborator 1 on grayscale. Updates
are AE-compressed every communication round; the sawtooth accuracy/loss
pattern (dip after each aggregation) shows federation is really happening
while the pipe carries only latents.

Run: PYTHONPATH=src python examples/fl_color_imbalance.py [--rounds N]
"""
import argparse

import jax

from repro.configs.paper import CIFAR_CLASSIFIER, cifar_ae_for
from repro.core import FCAECompressor, FLConfig, FederatedRun, run_prepass
from repro.data.pipeline import cifar_like, color_imbalance_split
from repro.models.classifiers import init_classifier, n_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--n", type=int, default=256, help="samples/collab")
    args = ap.parse_args()

    P = n_params(init_classifier(jax.random.PRNGKey(0), CIFAR_CLASSIFIER))
    ae_cfg = cifar_ae_for(P)
    print(f"== 2-collaborator FL, CIFAR-CNN {P} params, "
          f"AE {ae_cfg.n_params} params, {ae_cfg.compression_ratio:.0f}x ==")

    datasets, eval_data = color_imbalance_split(0, args.n)
    comps = []
    for ci, d in enumerate(datasets):
        kind = "color" if ci == 0 else "grayscale"
        print(f"pre-pass for collaborator {ci} ({kind}) ...")
        out = run_prepass(jax.random.PRNGKey(10 + ci), CIFAR_CLASSIFIER,
                          ae_cfg, d, prepass_epochs=5, ae_epochs=6)
        comps.append(FCAECompressor(out["ae_params"], ae_cfg))

    run = FederatedRun(
        CIFAR_CLASSIFIER, datasets,
        FLConfig(n_rounds=args.rounds, local_epochs=args.local_epochs,
                 payload="weights"),    # paper §5.2: converged weights
        compressors=comps, eval_data=eval_data)

    def progress(rec):
        cacc = [m.get("accuracy", 0.0) for m in rec.collab_metrics]
        print(f"round {rec.round:3d}: global_acc="
              f"{rec.global_metrics['accuracy']:.3f} "
              f"collab_acc={[f'{a:.3f}' for a in cacc]} "
              f"ratio={rec.compression_ratio:.0f}x")

    run.run(progress)
    totals = run.total_bytes()
    print(f"total upstream bytes: {totals['bytes_up']:.2e} "
          f"(raw {totals['bytes_up_raw']:.2e}) -> effective "
          f"{totals['effective_ratio']:.0f}x reduction")


if __name__ == "__main__":
    main()

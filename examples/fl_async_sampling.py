"""Scalable federated runtime demo: client sampling + async aggregation.

Runs the same 16-client non-IID federation under all three round schedulers
(DESIGN.md §6) with int8-quantized updates and compares accuracy against
communication cost:

1. SyncFedAvg     — every client every round (the seed/paper baseline),
2. SampledSync    — a 4-of-16 cohort per round, vmap-batched local training,
3. AsyncBuffered  — FedBuff-style K=4 buffer over a latency model where a
   25% straggler tail is 8x slower; staleness-weighted aggregation keeps
   the fast clients from waiting on the slow ones.

Every RoundRecord carries up/down byte accounting and the compression
ratio; async records add participant staleness and the simulated clock.

Run: PYTHONPATH=src python examples/fl_async_sampling.py
"""
from repro.configs.paper import MNIST_CLASSIFIER, SMOKE_SCALE_SCENARIO
from repro.core import (AsyncBuffered, FLConfig, FederatedRun, LatencyModel,
                        QuantizeCompressor, SampledSync, SyncFedAvg)
from repro.data.pipeline import mnist_like, train_eval_split, \
    uniform_partition


def run_one(name, scheduler, data, eval_data, cfg):
    run = FederatedRun(
        MNIST_CLASSIFIER, data, cfg,
        compressors=[QuantizeCompressor(bits=8)
                     for _ in range(len(data))],
        eval_data=eval_data, scheduler=scheduler)
    hist = run.run()
    tot = run.total_bytes()
    print(f"\n== {name} ==")
    for rec in hist:
        extra = ""
        if rec.staleness is not None:
            extra = (f"  staleness={rec.staleness}"
                     f"  t={rec.sim_time:.2f}")
        print(f"round {rec.round}: acc={rec.global_metrics['accuracy']:.3f}"
              f"  up={rec.bytes_up / 1e3:.0f}kB"
              f"  down={rec.bytes_down / 1e3:.0f}kB"
              f"  ratio={rec.compression_ratio:.1f}x"
              f"  cohort={rec.participants}{extra}")
    print(f"totals: up={tot['bytes_up'] / 1e3:.0f}kB "
          f"down={tot['bytes_down'] / 1e3:.0f}kB "
          f"effective_ratio={tot['effective_ratio']:.1f}x")
    return hist


def main():
    sc = SMOKE_SCALE_SCENARIO
    print(f"scenario: {sc.n_clients} clients, cohort {sc.cohort}, "
          f"buffer K={sc.buffer_k}, {sc.rounds} rounds, "
          f"{sc.straggler_frac:.0%} stragglers {sc.straggler_mult:.0f}x slow")
    # equal-sized shards: the homogeneous layout the vmap cohort path needs
    # (swap in dirichlet_partition for label-skew experiments — SampledSync
    # then falls back to the per-client loop automatically)
    train, eval_data = train_eval_split(mnist_like(0, 2048), 256)
    data = uniform_partition(0, train, sc.n_clients)
    cfg = FLConfig(n_rounds=sc.rounds, local_epochs=sc.local_epochs,
                   lr=2e-3, payload="update")

    run_one("SyncFedAvg (all 16 every round)", SyncFedAvg(),
            data, eval_data, cfg)
    sampled = SampledSync(cohort=sc.cohort)
    run_one(f"SampledSync ({sc.cohort}-of-{sc.n_clients}, vmap cohort)",
            sampled, data, eval_data, cfg)
    print(f"(vmap fast path took {sampled.vmap_rounds}/"
          f"{sampled.vmap_rounds + sampled.loop_rounds} rounds)")
    run_one(f"AsyncBuffered (K={sc.buffer_k}, straggler tail)",
            AsyncBuffered(
                buffer_k=sc.buffer_k,
                latency=LatencyModel(base=sc.base_latency,
                                     jitter=sc.latency_jitter,
                                     straggler_frac=sc.straggler_frac,
                                     straggler_mult=sc.straggler_mult)),
            data, eval_data, cfg)


if __name__ == "__main__":
    main()

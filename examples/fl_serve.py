"""Streaming FL ingest demo (DESIGN.md §12.3): the million-client serving
pipeline at laptop scale.

A population of N clients streams encoded weight updates at the server; the
first-K buffer fires one donated jitted step — device-side first-K pop
(``pop_k_device``), synthetic encoded cohort, fused decode→aggregate,
staleness-weighted model update, re-dispatch of exactly the drained cohort —
and the loop reports sustained rounds/sec and ingested uplink bytes/sec.
Per-round HOST work is one dispatch of a cached executable, independent of
both population and cohort size.

This is FL *serving* throughput. The LLM token-serving demo that used to
own this filename is ``examples/llm_serve_decode.py`` (prefill/decode with
a KV cache); the two share nothing but the word "serve".

Run: PYTHONPATH=src python examples/fl_serve.py
     PYTHONPATH=src python examples/fl_serve.py --n-clients 1000000 \
         --buffer-k 4096 --spec topk
"""
import argparse

from repro.core import codec
from repro.core.serve import ServeConfig, round_bytes, run_serve


def make_spec(kind: str, size: int):
    return {
        "q8": lambda: codec.QuantizeSpec(size=size, bits=8, block=256),
        "q4": lambda: codec.QuantizeSpec(size=size, bits=4, block=256),
        "topk": lambda: codec.TopKSpec(size=size, k=max(size // 64, 1)),
        "identity": lambda: codec.IdentitySpec(size=size),
    }[kind]()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-clients", type=int, default=100_000)
    ap.add_argument("--buffer-k", type=int, default=256)
    ap.add_argument("--model-size", type=int, default=4096)
    ap.add_argument("--spec", default="q8",
                    choices=["q8", "q4", "topk", "identity"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--straggler-frac", type=float, default=0.05)
    ap.add_argument("--shard", action="store_true",
                    help="shard_map the cohort axis over local devices")
    args = ap.parse_args()

    spec = make_spec(args.spec, args.model_size)
    cfg = ServeConfig(n_clients=args.n_clients, buffer_k=args.buffer_k,
                      spec=spec, jitter=0.4,
                      straggler_frac=args.straggler_frac, seed=0,
                      shard=args.shard)
    print(f"population N={args.n_clients}  cohort K={args.buffer_k}  "
          f"codec={args.spec}({args.model_size})  "
          f"round uplink={round_bytes(cfg) / 1e6:.2f} MB")

    state, rep = run_serve(cfg, n_rounds=args.rounds, warmup=2)
    print(f"sustained: {rep['rounds_per_sec']:.2f} rounds/s  "
          f"{rep['bytes_per_sec'] / 1e6:.2f} MB/s ingested  "
          f"({rep['us_per_round'] / 1e3:.2f} ms/round)")
    print(f"model version {int(state['version'])}, "
          f"sim clock {rep['sim_time']:.1f}s simulated "
          f"({int(state['version']) * args.buffer_k} updates aggregated)")


if __name__ == "__main__":
    main()

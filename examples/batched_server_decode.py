"""Batched server decode→aggregate demo (DESIGN.md §7).

Builds a 64-client cohort of chunked-AE payloads for one simulated round and
runs the aggregator three ways:

1. per-client loop  — the seed server: one decode dispatch per client, then
   a Python accumulation (the path the refactor retires),
2. fused one-call   — ``codec.decode_and_aggregate``: stack the cohort's
   payloads and decode + FedAvg-reduce in a single jitted call,
3. shard_map        — ``codec.decode_and_aggregate_sharded``: the client
   axis split over the local device mesh with a psum epilogue.

All three agree to float tolerance; the timing gap is the point. On CPU the
Pallas kernels run in interpret mode — on TPU the fused path compiles
natively (``REPRO_USE_KERNEL=1`` forces the kernel path anywhere).

Run: PYTHONPATH=src python examples/batched_server_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import codec, normalize_weights
from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae

COHORT = 64
MODEL = 1 << 15                         # flat update length per client


def timed(fn, n=3):
    fn()                                # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def main():
    cfg = ChunkedAEConfig(chunk_size=256, hidden=(32,), latent_chunk=8)
    params = init_chunked_ae(jax.random.PRNGKey(0), cfg)
    jnp_spec = codec.ChunkedAESpec(size=MODEL, cfg=cfg, use_kernel=False)
    kern_spec = codec.ChunkedAESpec(size=MODEL, cfg=cfg, use_kernel=True)
    print(f"== cohort {COHORT}, {MODEL}-param updates, "
          f"{cfg.compression_ratio:.0f}x chunked AE ==")

    base = jax.random.normal(jax.random.PRNGKey(1), (MODEL,))
    payloads = [codec.encode(jnp_spec, params, base * (1 + 0.01 * i))
                for i in range(COHORT)]
    stacked = codec.stack_payloads(payloads)
    weights = normalize_weights([float(i + 1) for i in range(COHORT)])
    nw = jnp.asarray(weights, jnp.float32)
    up_bytes = sum(sum(x.size * x.dtype.itemsize for x in p.values())
                   for p in payloads)
    print(f"uplink this round: {up_bytes / 1e3:.0f} kB compressed "
          f"vs {COHORT * MODEL * 4 / 1e3:.0f} kB raw")

    def loop():
        acc = jnp.zeros((MODEL,), jnp.float32)
        for w, p in zip(weights, payloads):
            acc = acc + w * codec.decode(jnp_spec, params, p)
        return jax.block_until_ready(acc)

    def fused():
        return jax.block_until_ready(
            codec.decode_and_aggregate(kern_spec, params, stacked, nw))

    def sharded():
        return jax.block_until_ready(
            codec.decode_and_aggregate_sharded(jnp_spec, params, stacked,
                                               nw))

    ref = loop()
    t_loop = timed(loop)
    print(f"per-client loop : {t_loop * 1e3:8.1f} ms/round  (seed server)")
    for name, fn in (("fused one-call", fused), ("shard_map", sharded)):
        out = fn()
        err = float(jnp.max(jnp.abs(out - ref)))
        t = timed(fn)
        print(f"{name:16s}: {t * 1e3:8.1f} ms/round  "
              f"({t_loop / t:4.1f}x vs loop, max|Δ|={err:.2e})")


if __name__ == "__main__":
    main()
